"""Paged prefill-attention kernel: interpret-mode parity with the oracle.

The kernel serves the mixed prefill+decode serving step (DESIGN §11):
per-slot query chunks against the shared block pool, block tables and
per-slot (q_offset, kv_valid_len) as scalar prefetch, intra-chunk causal
masking on top of the cache frontier. The sweeps cover GQA group sizes,
ragged offsets/lengths (decode slots as degenerate one-token chunks),
shared and sentinel table entries, and bf16 inputs; the oracle itself is
pinned against plain dense causal attention on a contiguous cache.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.prefill_attention import paged_prefill_attention_pallas
from repro.models.attention import dense_attention


def _pool_case(rng, b, c, h, hkv, hd, nblk, page, npages, dtype):
    q = jnp.asarray(rng.normal(size=(b, c, h, hd)), dtype)
    kp = jnp.asarray(rng.normal(size=(nblk, page, hkv, hd)), dtype)
    vp = jnp.asarray(rng.normal(size=(nblk, page, hkv, hd)), dtype)
    table = jnp.asarray(rng.integers(0, nblk, size=(b, npages)), jnp.int32)
    qoff = jnp.asarray(
        rng.integers(0, page * npages - c + 1, size=(b,)), jnp.int32
    )
    vl = qoff + jnp.asarray(rng.integers(1, c + 1, size=(b,)), jnp.int32)
    return q, kp, vp, table, qoff, vl


@pytest.mark.parametrize("g", [1, 2, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_prefill_kernel_matches_ref(g, dtype):
    rng = np.random.default_rng(7 + g)
    hkv, hd, page, npages, nblk = 2, 16, 4, 6, 14
    q, kp, vp, table, qoff, vl = _pool_case(
        rng, 3, 8, g * hkv, hkv, hd, nblk, page, npages, dtype
    )
    want = ref.paged_prefill_attention_ref(q, kp, vp, table, qoff, vl)
    got = paged_prefill_attention_pallas(
        q, kp, vp, table, qoff, vl, interpret=True
    )
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


def test_paged_prefill_kernel_shared_and_sentinel_pages():
    """Two slots routing through the SAME physical blocks must read the
    same values; sentinel (unallocated) entries clamp and stay masked
    behind the valid length."""
    rng = np.random.default_rng(11)
    b, c, h, hkv, hd, nblk, page, npages = 2, 6, 4, 2, 8, 9, 4, 4
    q = jnp.asarray(rng.normal(size=(b, c, h, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nblk, page, hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nblk, page, hkv, hd)), jnp.float32)
    # slot 1 shares slot 0's first two pages; tails diverge, last page of
    # slot 0 is the out-of-range sentinel (never reached: vl stops before)
    table = jnp.asarray([[3, 5, 1, nblk], [3, 5, 7, 2]], jnp.int32)
    qoff = jnp.asarray([8, 6], jnp.int32)
    vl = jnp.asarray([12, 12], jnp.int32)
    want = ref.paged_prefill_attention_ref(q, kp, vp, table, qoff, vl)
    got = paged_prefill_attention_pallas(
        q, kp, vp, table, qoff, vl, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5
    )


def test_mixed_roles_one_call():
    """A decode slot is the degenerate chunk q_len = 1: its single real
    row must equal the decode-attention oracle over the same pool."""
    rng = np.random.default_rng(3)
    b, c, h, hkv, hd, nblk, page, npages = 2, 4, 4, 2, 8, 8, 4, 4
    q = jnp.asarray(rng.normal(size=(b, c, h, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nblk, page, hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nblk, page, hkv, hd)), jnp.float32)
    table = jnp.asarray(rng.integers(0, nblk, size=(b, npages)), jnp.int32)
    # slot 0 decodes at position 9 (q_len 1); slot 1 prefills a 4-chunk
    qoff = jnp.asarray([9, 4], jnp.int32)
    vl = jnp.asarray([10, 8], jnp.int32)
    got = paged_prefill_attention_pallas(
        q, kp, vp, table, qoff, vl, interpret=True
    )
    dec = ref.paged_decode_attention_ref(
        q[:1, :1], kp, vp, table[:1], jnp.asarray([10], jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(got[0, 0]), np.asarray(dec[0, 0]), atol=2e-5, rtol=2e-5
    )


def test_prefill_ref_matches_dense_causal_attention():
    """On a contiguous cache whose frontier equals the chunk end, the
    chunked oracle at q_offset=0 IS plain dense causal attention."""
    rng = np.random.default_rng(5)
    b, s, h, hkv, hd = 2, 12, 4, 2, 16

    class _Cfg:  # dense_attention only reads nothing from cfg
        pass

    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    want = dense_attention(q, k, v, causal=True)
    got = ref.prefill_attention_ref(
        q, k, v, jnp.zeros((b,), jnp.int32), jnp.full((b,), s, jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5
    )


def test_vector_q_offset_matches_shifted_scalar():
    """dense_attention's per-slot q_offset must reproduce the scalar
    variant row by row."""
    rng = np.random.default_rng(9)
    b, sq, skv, h, hkv, hd = 3, 4, 16, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, skv, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, skv, hkv, hd)), jnp.float32)
    offs = jnp.asarray([0, 5, 11], jnp.int32)
    got = dense_attention(q, k, v, causal=True, q_offset=offs)
    for i, o in enumerate([0, 5, 11]):
        want = dense_attention(
            q[i : i + 1], k[i : i + 1], v[i : i + 1], causal=True, q_offset=o
        )
        np.testing.assert_allclose(
            np.asarray(got[i]), np.asarray(want[0]), atol=2e-6, rtol=2e-6
        )
