"""Pallas flash-attention kernel sweeps vs the dense oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (
    flash_attention_fwd_pallas,
    flash_attention_gqa_pallas,
)
from repro.models.attention import dense_attention

RNG = np.random.default_rng(11)

CASES = [
    # (B, S, H, Hkv, hd, causal)
    (2, 128, 4, 2, 16, True),
    (2, 128, 4, 2, 16, False),
    (1, 256, 8, 8, 32, True),
    (2, 128, 8, 2, 64, True),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_flash_gqa_matches_dense(case, dt):
    b, s, h, hkv, hd, causal = case
    q = jnp.asarray(RNG.normal(size=(b, s, h, hd)), dt)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, hd)), dt)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, hd)), dt)
    want = dense_attention(q, k, v, causal=causal)
    got = flash_attention_gqa_pallas(
        q, k, v, causal=causal, block_q=64, block_k=64, interpret=True
    )
    atol = 1e-4 if dt == jnp.float32 else 0.05
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=atol
    )


@pytest.mark.parametrize("bq,bk", [(32, 64), (64, 32), (128, 128)])
def test_flash_block_shape_invariance(bq, bk):
    b, s, hd = 3, 128, 16
    q = jnp.asarray(RNG.normal(size=(b, s, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hd)), jnp.float32)
    ref = flash_attention_fwd_pallas(
        q, k, v, causal=True, block_q=128, block_k=128, interpret=True
    )
    got = flash_attention_fwd_pallas(
        q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_flash_rejects_ragged():
    q = jnp.zeros((1, 100, 16))
    with pytest.raises(ValueError):
        flash_attention_fwd_pallas(q, q, q, block_q=64, block_k=64, interpret=True)
