"""fused dequant×matmul+delta sweeps: jnp oracle vs Pallas interpret, both
vs ``fused_linear`` on the dequantized base, plus the sparse-only VJP."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.quant_linear import fused_linear_q_pallas
from repro.quant import dequantize, quantize

RNG = np.random.default_rng(23)

SHAPES = [
    # (M, d_in, d_out, k)
    (128, 128, 128, 1),
    (256, 384, 256, 4),
    (128, 512, 384, 8),
]
QDTYPES = ["int8", "nf4"]


def _mk(m, d_in, d_out, k, dt=jnp.float32):
    x = jnp.asarray(RNG.normal(size=(m, d_in)), dt)
    w = jnp.asarray(RNG.normal(size=(d_in, d_out)) * 0.05, dt)
    idx = jnp.asarray(RNG.integers(0, d_in, size=(k, d_out)), jnp.int32)
    val = jnp.asarray(RNG.normal(size=(k, d_out)), dt)
    b = jnp.asarray(RNG.normal(size=(d_out,)), dt)
    return x, w, idx, val, b


def _rel_err(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    return np.abs(got - want).max() / max(np.abs(want).max(), 1e-6)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("qdtype", QDTYPES)
def test_fused_linear_q_matches_dequantized_fused_linear(shape, qdtype):
    """Acceptance bound: ≤1e-2 rel error vs fused_linear on the dequantized
    base, on the jnp and pallas_interpret backends."""
    x, w, idx, val, b = _mk(*shape)
    qw = quantize(w, qdtype, 64)
    want = ref.fused_linear_ref(x, dequantize(qw), idx, val, b)
    got_jnp = ops.fused_linear_q(x, qw, idx, val, b)
    assert _rel_err(got_jnp, want) <= 1e-2
    with ops.use_backend("pallas_interpret"):
        got_pi = ops.fused_linear_q(x, qw, idx, val, b)
    assert _rel_err(got_pi, want) <= 1e-2
    assert ops.get_backend() == "jnp"


@pytest.mark.parametrize("qdtype", QDTYPES)
def test_fused_linear_q_pallas_direct_no_bias(qdtype):
    x, w, idx, val, _ = _mk(128, 256, 128, 2)
    qw = quantize(w, qdtype, 64)
    got = fused_linear_q_pallas(
        x, qw.data, qw.scales, idx, val, None,
        qdtype=qdtype, block=64, block_k=128, interpret=True,
    )
    want = ref.fused_linear_ref(x, dequantize(qw), idx, val, None)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=2e-3
    )


def test_fused_linear_q_bf16_activations():
    x, w, idx, val, b = _mk(128, 256, 128, 2, jnp.bfloat16)
    qw = quantize(w, "int8", 64)
    want = ref.fused_linear_ref(x, dequantize(qw).astype(jnp.bfloat16), idx, val, b)
    with ops.use_backend("pallas_interpret"):
        got = ops.fused_linear_q(x, qw, idx, val, b)
    assert got.dtype == jnp.bfloat16
    assert _rel_err(got, want) <= 0.1  # bf16 mantissa tolerance


def test_fused_linear_q_batch_dims_and_padding():
    x = jnp.asarray(RNG.normal(size=(2, 5, 128)), jnp.float32)  # ragged M
    w = jnp.asarray(RNG.normal(size=(128, 128)) * 0.05, jnp.float32)
    idx = jnp.asarray(RNG.integers(0, 128, size=(3, 128)), jnp.int32)
    val = jnp.asarray(RNG.normal(size=(3, 128)), jnp.float32)
    qw = quantize(w, "int8", 64)
    want = ops.fused_linear_q(x, qw, idx, val)
    assert want.shape == (2, 5, 128)
    with ops.use_backend("pallas_interpret"):
        got = ops.fused_linear_q(x, qw, idx, val)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("qdtype", QDTYPES)
def test_matmul_q_backends(qdtype):
    x, w, *_ = _mk(128, 256, 128, 1)
    qw = quantize(w, qdtype, 64)
    want = jnp.dot(x, dequantize(qw))
    got_jnp = ops.matmul_q(x, qw)
    with ops.use_backend("pallas_interpret"):
        got_pi = ops.matmul_q(x, qw)
    np.testing.assert_allclose(np.asarray(got_jnp), np.asarray(want), atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_pi), np.asarray(want), atol=1e-3)
    # plain arrays pass straight through
    np.testing.assert_allclose(
        np.asarray(ops.matmul_q(x, w)), np.asarray(jnp.dot(x, w)), atol=1e-5
    )


def test_matmul_q_differentiable_on_pallas_backend():
    """matmul_q sits in training forward paths (LoRA / untied heads on a
    quantized base): it must be differentiable on the Pallas backends too
    (it routes through the fused custom-VJP wrapper with a zero bypass)."""
    x, w, *_ = _mk(16, 128, 64, 1)
    qw = quantize(w, "int8", 64)

    def f(xx):
        return jnp.sum(jnp.sin(ops.matmul_q(xx, qw)))

    g_ref = jax.grad(f)(x)
    with ops.use_backend("pallas_interpret"):
        g = jax.grad(f)(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-3)


def test_fused_linear_q_vjp_matches_jnp_backend():
    """Training on a quantized base: the Pallas custom VJP must reproduce
    the jnp-backend grads (which autodiff through the dequant) for x/val."""
    x, w, idx, val, b = _mk(256, 384, 256, 3)
    qw = quantize(w, "int8", 64)

    def f(xx, vv):
        return jnp.sum(jnp.cos(ops.fused_linear_q(xx, qw, idx, vv, b)))

    g_jnp = jax.grad(f, argnums=(0, 1))(x, val)
    with ops.use_backend("pallas_interpret"):
        g_pi = jax.grad(f, argnums=(0, 1))(x, val)
    np.testing.assert_allclose(np.asarray(g_jnp[0]), np.asarray(g_pi[0]), atol=1e-3)
    np.testing.assert_allclose(np.asarray(g_jnp[1]), np.asarray(g_pi[1]), atol=1e-3)


def test_fused_linear_frozen_w_skips_dense_dw():
    """w_frozen=True statically skips the dense dw matmul (zeros grad) while
    leaving dx/dval untouched — the guard fused_linear_q mirrors."""
    x, w, idx, val, b = _mk(128, 256, 128, 2)
    with ops.use_backend("pallas_interpret"):
        gw_frozen = jax.grad(
            lambda ww: jnp.sum(ops.fused_linear(x, ww, idx, val, b, w_frozen=True))
        )(w)
        gx_frozen, gv_frozen = jax.grad(
            lambda xx, vv: jnp.sum(ops.fused_linear(xx, w, idx, vv, b, w_frozen=True)),
            argnums=(0, 1),
        )(x, val)
        gx, gv = jax.grad(
            lambda xx, vv: jnp.sum(ops.fused_linear(xx, w, idx, vv, b)),
            argnums=(0, 1),
        )(x, val)
    assert np.all(np.asarray(gw_frozen) == 0)
    np.testing.assert_allclose(np.asarray(gx_frozen), np.asarray(gx))
    np.testing.assert_allclose(np.asarray(gv_frozen), np.asarray(gv))


def test_use_backend_restores_on_exception():
    assert ops.get_backend() == "jnp"
    with pytest.raises(RuntimeError):
        with ops.use_backend("pallas_interpret"):
            assert ops.get_backend() == "pallas_interpret"
            raise RuntimeError("sweep failure")
    assert ops.get_backend() == "jnp"  # no leak into later tests
    with pytest.raises(ValueError):
        with ops.use_backend("not-a-backend"):
            pass
    assert ops.get_backend() == "jnp"
