"""Hypothesis property tests on NeuroAda's core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core import Delta, delta_matmul, merge, scatter_to_dense, topk_indices

dims = st.tuples(
    st.integers(2, 24),  # d_in
    st.integers(1, 12),  # d_out
    st.integers(1, 32),  # batch
    st.integers(0, 2**31 - 1),  # seed
)


@given(dims, st.integers(1, 6))
def test_merge_equivalence(d, k):
    d_in, d_out, b, seed = d
    k = min(k, d_in)
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.normal(size=(d_in, d_out)), jnp.float32)
    idx = topk_indices(w, k)
    val = jnp.asarray(r.normal(size=(k, d_out)), jnp.float32)
    delta = Delta(idx, val)
    x = jnp.asarray(r.normal(size=(b, d_in)), jnp.float32)
    lhs = x @ merge(w, delta)
    rhs = x @ w + delta_matmul(x, delta)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-4)


@given(dims, st.integers(1, 6))
def test_scatter_preserves_l0(d, k):
    """‖Δ‖₀ ≤ k·d_out exactly (Eq. 1): compact form == sparse dense form."""
    d_in, d_out, _, seed = d
    k = min(k, d_in)
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.normal(size=(d_in, d_out)), jnp.float32)
    idx = topk_indices(w, k)
    val = jnp.asarray(r.normal(size=(k, d_out)) + 3.0, jnp.float32)  # nonzero
    dense = np.asarray(scatter_to_dense(Delta(idx, val), d_in))
    assert np.count_nonzero(dense) == k * d_out


@given(dims)
def test_every_neuron_covered(d):
    """Paper's core claim: k>=1 gives every neuron a trainable input."""
    d_in, d_out, _, seed = d
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.normal(size=(d_in, d_out)), jnp.float32)
    idx = np.asarray(topk_indices(w, 1))
    assert idx.shape == (1, d_out)
    assert np.all((0 <= idx) & (idx < d_in))


@given(dims, st.integers(1, 4))
def test_grad_sparsity(d, k):
    """dL/dΔ touches only selected coordinates — scatter grads land only at
    idx positions when mapped to dense space."""
    d_in, d_out, b, seed = d
    k = min(k, d_in)
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.normal(size=(d_in, d_out)), jnp.float32)
    idx = topk_indices(w, k)
    x = jnp.asarray(r.normal(size=(b, d_in)), jnp.float32)

    def dense_loss(dense_delta):
        return jnp.sum(jnp.sin(x @ (w + dense_delta)))

    def sparse_loss(val):
        return jnp.sum(jnp.sin(x @ w + delta_matmul(x, Delta(idx, val))))

    val0 = jnp.zeros((k, d_out), jnp.float32)
    g_sparse = jax.grad(sparse_loss)(val0)
    g_dense = jax.grad(dense_loss)(jnp.zeros((d_in, d_out)))
    picked = jnp.take_along_axis(g_dense, idx, axis=0)
    np.testing.assert_allclose(np.asarray(g_sparse), np.asarray(picked), atol=1e-4)
