import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Delta,
    delta_matmul,
    init_delta,
    merge,
    scatter_to_dense,
    topk_indices,
    init_adapters,
    merge_adapters,
    zip_adapters,
    count_trainable,
    count_total,
)

RNG = np.random.default_rng(1)


def _delta(d_in=24, d_out=12, k=3):
    w = jnp.asarray(RNG.normal(size=(d_in, d_out)), jnp.float32)
    idx = topk_indices(w, k)
    val = jnp.asarray(RNG.normal(size=(k, d_out)), jnp.float32)
    return w, Delta(idx, val)


def test_zero_init_is_identity():
    w, d = _delta()
    d0 = init_delta(d.idx)
    x = jnp.asarray(RNG.normal(size=(5, 24)), jnp.float32)
    assert np.allclose(delta_matmul(x, d0), 0.0)
    assert np.allclose(merge(w, d0), w)


def test_delta_equals_dense_scatter():
    w, d = _delta()
    x = jnp.asarray(RNG.normal(size=(5, 24)), jnp.float32)
    dense = scatter_to_dense(d, 24)
    np.testing.assert_allclose(delta_matmul(x, d), x @ dense, atol=1e-5)


def test_merge_equals_forward_sum():
    w, d = _delta()
    x = jnp.asarray(RNG.normal(size=(5, 24)), jnp.float32)
    np.testing.assert_allclose(
        x @ merge(w, d), x @ w + delta_matmul(x, d), atol=1e-5
    )


def test_grads_flow_only_to_values():
    w, d = _delta()
    x = jnp.asarray(RNG.normal(size=(5, 24)), jnp.float32)

    def loss(val):
        return jnp.sum(jnp.tanh(x @ w + delta_matmul(x, Delta(d.idx, val))))

    g = jax.grad(loss)(d.val)
    assert g.shape == d.val.shape and np.any(np.asarray(g) != 0)


def test_adapter_tree_roundtrip():
    params = {
        "blocks": {
            "wq": {"w": jnp.asarray(RNG.normal(size=(4, 16, 8)), jnp.float32)},
            "attn_norm": jnp.ones((4, 16)),
        },
        "embed": {"w": jnp.asarray(RNG.normal(size=(32, 16)), jnp.float32)},
    }
    ind, vals = init_adapters(params, 2)
    assert ind["blocks"]["wq"]["w"].shape == (4, 2, 8)
    assert ind["blocks"]["attn_norm"] is None
    assert ind["embed"]["w"] is None  # excluded
    assert count_trainable(vals) == 4 * 2 * 8
    assert count_total(params) > 0
    # zero-init merge is identity
    merged = merge_adapters(params, ind, vals)
    np.testing.assert_allclose(
        np.asarray(merged["blocks"]["wq"]["w"], np.float32),
        np.asarray(params["blocks"]["wq"]["w"], np.float32),
    )
    ad = zip_adapters(ind, vals)
    assert isinstance(ad["blocks"]["wq"]["w"], Delta)
    assert ad["embed"]["w"] is None
