import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topk_indices, k_for_budget

RNG = np.random.default_rng(0)


def test_top1_matches_argmax():
    w = jnp.asarray(RNG.normal(size=(64, 32)), jnp.float32)
    idx = topk_indices(w, 1)
    assert idx.shape == (1, 32)
    np.testing.assert_array_equal(
        np.asarray(idx[0]), np.argmax(np.abs(np.asarray(w)), axis=0)
    )


@pytest.mark.parametrize("k", [1, 3, 7])
def test_topk_indices_unique_and_sorted_by_magnitude(k):
    w = jnp.asarray(RNG.normal(size=(40, 16)), jnp.float32)
    idx = np.asarray(topk_indices(w, k))
    aw = np.abs(np.asarray(w))
    for col in range(16):
        sel = idx[:, col]
        assert len(set(sel.tolist())) == k  # unique
        mags = aw[sel, col]
        assert np.all(np.diff(mags) <= 1e-7)  # descending
        # every selected >= every unselected
        unsel = np.setdiff1d(np.arange(40), sel)
        assert mags.min() >= aw[unsel, col].max() - 1e-7


def test_stacked_leading_dims():
    w = jnp.asarray(RNG.normal(size=(3, 5, 20, 8)), jnp.float32)
    idx = topk_indices(w, 2)
    assert idx.shape == (3, 5, 2, 8)
    # spot check one slice
    ref = topk_indices(w[1, 2], 2)
    np.testing.assert_array_equal(np.asarray(idx[1, 2]), np.asarray(ref))


def test_reverse_picks_smallest():
    w = jnp.asarray(RNG.normal(size=(30, 4)), jnp.float32)
    idx = np.asarray(topk_indices(w, 1, strategy="reverse"))
    np.testing.assert_array_equal(idx[0], np.argmin(np.abs(np.asarray(w)), axis=0))


def test_gradient_strategy_uses_grad():
    w = jnp.asarray(RNG.normal(size=(30, 4)), jnp.float32)
    g = jnp.zeros_like(w).at[7].set(100.0)
    idx = np.asarray(topk_indices(w, 1, strategy="gradient", grad=g))
    assert np.all(idx[0] == 7)


def test_random_strategy_unique_and_seeded():
    w = jnp.ones((50, 8))
    i1 = topk_indices(w, 5, strategy="random", rng=jax.random.PRNGKey(0))
    i2 = topk_indices(w, 5, strategy="random", rng=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    for col in range(8):
        assert len(set(np.asarray(i1)[:, col].tolist())) == 5


def test_k_for_budget():
    shapes = {"a": (100, 50), "b": (100, 50)}
    total = 2 * 100 * 50
    k = k_for_budget(total, shapes, 0.01)
    assert k == 1
    k = k_for_budget(total, shapes, 0.5)
    assert k == 50


def test_bad_inputs():
    w = jnp.ones((8, 4))
    with pytest.raises(ValueError):
        topk_indices(w, 0)
    with pytest.raises(ValueError):
        topk_indices(w, 9)
    with pytest.raises(ValueError):
        topk_indices(w, 1, strategy="nope")
    with pytest.raises(ValueError):
        topk_indices(w, 1, strategy="gradient")
