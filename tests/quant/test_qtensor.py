"""Quantizer unit tests: roundtrip error bounds, packing, trees, pytree
mechanics (scan slicing, jit), and checkpoint save/restore of packed trees."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant import (
    QuantizedTensor,
    any_quantized,
    dequantize,
    dequantize_tree,
    quantize,
    quantize_tree,
    tree_bytes,
    unpack_nf4,
)

RNG = np.random.default_rng(11)


def _w(shape, scale=0.05, dt=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape) * scale, dt)


# ------------------------------------------------------------- roundtrip


@pytest.mark.parametrize("shape", [(128, 96), (3, 128, 64), (100, 70)])
@pytest.mark.parametrize("block", [32, 64])
def test_int8_roundtrip_bounded(shape, block):
    w = _w(shape)
    qt = quantize(w, "int8", block)
    wd = dequantize(qt)
    assert wd.shape == w.shape and wd.dtype == w.dtype
    # symmetric int8: per-element error <= scale/2 = absmax/254 per block;
    # bound globally by the worst block's absmax
    err = np.abs(np.asarray(wd - w))
    bound = float(jnp.max(qt.scales)) / 2 + 1e-7
    assert err.max() <= bound, (err.max(), bound)


@pytest.mark.parametrize("shape", [(128, 96), (3, 128, 64)])
def test_nf4_roundtrip_bounded(shape):
    w = _w(shape)
    qt = quantize(w, "nf4", 64)
    wd = dequantize(qt)
    assert wd.shape == w.shape
    # NF4's widest decision cell is ~0.14 of the block absmax (around ±1)
    err = np.abs(np.asarray(wd - w))
    bound = 0.15 * float(jnp.max(qt.scales))
    assert err.max() <= bound, (err.max(), bound)
    # and the codebook is actually 4-bit: data holds two codes per byte
    assert qt.data.dtype == jnp.uint8
    assert qt.data.shape[-2] == shape[-2] // 2


def test_nf4_exact_zero_and_pack_order():
    w = jnp.zeros((8, 4), jnp.float32).at[2, 1].set(0.5).at[3, 1].set(-0.5)
    qt = quantize(w, "nf4", 8)
    np.testing.assert_allclose(np.asarray(dequantize(qt)), np.asarray(w), atol=1e-6)
    codes = np.asarray(unpack_nf4(qt.data))
    assert codes.shape == (8, 4)
    assert codes[2, 1] == 15 and codes[3, 1] == 0  # ±absmax endpoints
    assert codes[0, 0] == 7  # zero maps to the exact-zero code


def test_int8_bf16_dtype_and_odd_blocks():
    w = _w((100, 48), dt=jnp.bfloat16)  # d_in not a block multiple
    qt = quantize(w, "int8", 64)
    assert qt.scales.shape == (2, 48)  # ceil(100/64)
    wd = dequantize(qt)
    assert wd.dtype == jnp.bfloat16 and wd.shape == (100, 48)


def test_nf4_odd_d_in_rejected():
    with pytest.raises(ValueError, match="even"):
        quantize(_w((7, 8)), "nf4", 4)


# ----------------------------------------------------------- pytree node


def test_scan_slices_packed_stacks():
    """lax.scan over a (L, …) quantized stack must yield per-layer tensors
    whose dequant equals slicing the full dequant — the property the layer
    scan in every model relies on."""
    w = _w((4, 128, 64))
    qt = quantize(w, "int8", 64)

    def body(c, per_layer):
        return c, dequantize(per_layer)

    _, per = jax.lax.scan(body, 0, qt)
    np.testing.assert_allclose(
        np.asarray(per), np.asarray(dequantize(qt)), atol=1e-6
    )


def test_jit_and_grad_through_dequantize():
    w = _w((64, 32))
    qt = quantize(w, "int8", 32)
    x = _w((8, 64), 1.0)
    y = jax.jit(lambda q, xx: xx @ dequantize(q))(qt, x)
    assert y.shape == (8, 32)
    # differentiating w.r.t. x through the dequant matmul works (int codes
    # are not differentiated — the trainer never asks for their grads)
    g = jax.grad(lambda xx: jnp.sum(jax.jit(lambda q, xx: xx @ dequantize(q))(qt, xx)))(x)
    assert g.shape == x.shape


def test_quantize_tree_policy_and_bytes():
    tree = {
        "blocks": {"wq": {"w": _w((2, 128, 64))}, "attn_norm": jnp.ones((2, 64))},
        "embed": {"w": _w((256, 64))},
        "head": {"w": _w((64, 256))},
    }
    qtree = quantize_tree(tree, "int8", 64)
    assert isinstance(qtree["blocks"]["wq"]["w"], QuantizedTensor)
    assert isinstance(qtree["head"]["w"], QuantizedTensor)
    assert not isinstance(qtree["embed"]["w"], QuantizedTensor)  # excluded
    assert not isinstance(qtree["blocks"]["attn_norm"], QuantizedTensor)
    assert any_quantized(qtree) and not any_quantized(tree)
    assert tree_bytes(qtree) < tree_bytes(tree)
    back = dequantize_tree(qtree)
    assert not any_quantized(back)
    assert back["blocks"]["wq"]["w"].shape == (2, 128, 64)


def test_quantize_tree_idempotent():
    """Re-quantizing an already-packed tree is a no-op, not a crash —
    ServeEngine(base_dtype=…) may receive params a launcher already packed."""
    tree = {"blocks": {"wq": {"w": _w((2, 128, 64))}}}
    q1 = quantize_tree(tree, "int8", 64)
    q2 = quantize_tree(q1, "int8", 64)
    assert q2["blocks"]["wq"]["w"] is q1["blocks"]["wq"]["w"]


def test_int8_blockwise_byte_reduction_vs_fp32():
    """Acceptance floor: >=3.5x over fp32 for the quantized leaves."""
    w = jnp.asarray(RNG.normal(size=(4, 128, 128)), jnp.float32)
    qt = quantize(w, "int8", 64)
    assert w.size * 4 / qt.nbytes >= 3.5
    nf4 = quantize(w, "nf4", 64)
    assert w.size * 4 / nf4.nbytes >= 6.0


# ----------------------------------------------------------- checkpointing


def test_checkpoint_roundtrip_packed(tmp_path):
    from repro.checkpoint.manager import load_pytree, restore_into, save_pytree

    tree = {
        "blocks": {"wq": {"w": quantize(_w((2, 128, 64), dt=jnp.bfloat16), "nf4", 64)}},
        "head": {"w": quantize(_w((64, 128)), "int8", 32)},
        "norm": jnp.ones((64,), jnp.bfloat16),
        "none_leaf": None,
    }
    p = os.path.join(tmp_path, "q.npz")
    save_pytree(p, tree, {"kind": "test"})
    loaded = load_pytree(p)
    qw = loaded["blocks"]["wq"]["w"]
    assert isinstance(qw, QuantizedTensor)
    assert qw.qdtype == "nf4" and qw.block == 64 and qw.dtype_name == "bfloat16"
    # packed bytes identical, therefore dequant identical
    np.testing.assert_array_equal(
        np.asarray(tree["blocks"]["wq"]["w"].data), np.asarray(qw.data)
    )
    np.testing.assert_array_equal(
        np.asarray(tree["head"]["w"].data), np.asarray(loaded["head"]["w"].data)
    )
    restored = restore_into(tree, loaded)
    np.testing.assert_allclose(
        np.asarray(dequantize(restored["head"]["w"])),
        np.asarray(dequantize(tree["head"]["w"])),
    )
    assert restored["none_leaf"] is None

    # a dense checkpoint cannot silently restore into a packed template…
    dense = {**tree, "head": {"w": _w((64, 128))}}
    pd = os.path.join(tmp_path, "d.npz")
    save_pytree(pd, dense)
    with pytest.raises(ValueError, match="QuantizedTensor"):
        restore_into(tree, load_pytree(pd))
    # …and a packed checkpoint into a dense template fails loudly too
    # (resuming without the run's --base-dtype), not with a numpy crash
    with pytest.raises(ValueError, match="dense array"):
        restore_into(dense, load_pytree(p))
    # …and a scheme/block mismatch is rejected rather than silently
    # adopting the checkpoint's packing over the requested one
    other = {**tree, "head": {"w": quantize(_w((64, 128)), "int8", 64)}}
    with pytest.raises(ValueError, match="block=64"):
        restore_into(other, load_pytree(p))
