"""Training on a quantized frozen base: only the sparse (val) leaves move,
the packed base stays bit-identical, and the loss goes down."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PeftConfig, TrainConfig, get_config, reduced
from repro.data.loader import DataLoader
from repro.models import get_model
from repro.peft import get_peft, quantize_base, stats
from repro.quant import QuantizedTensor, any_quantized, dequantize_tree, tree_bytes
from repro.train.trainer import Trainer

CFG = reduced(get_config("qwen2-1.5b"))


@pytest.fixture(scope="module")
def base():
    m = get_model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    return m, params


def _leaves(tree):
    return jax.tree.leaves(tree, is_leaf=lambda x: x is None)


def test_two_step_training_on_int8_base_reduces_loss(base):
    m, params = base
    qp = quantize_base(params, "int8")
    assert any_quantized(qp) and tree_bytes(qp) < tree_bytes(params)
    packed_before = [
        np.asarray(l.data).copy()
        for l in jax.tree.leaves(qp, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(l, QuantizedTensor)
    ]
    peft = get_peft(PeftConfig(method="neuroada", k=4))
    tcfg = TrainConfig(learning_rate=2e-2, steps=2, log_every=100)
    tr = Trainer(m, peft, tcfg, qp)
    data = DataLoader("reasoning", CFG.vocab_size, 32, 32, seed=0)
    hist = tr.run(data, steps=2)
    data.close()
    assert hist[-1]["loss"] < hist[0]["loss"], [h["loss"] for h in hist]
    # ONLY the (val) leaves trained: they moved off zero-init…
    moved = [
        float(jnp.max(jnp.abs(v)))
        for v in _leaves(tr.state.trainable)
        if v is not None
    ]
    assert max(moved) > 0
    # …and the packed base never changed a byte
    packed_after = [
        np.asarray(l.data)
        for l in jax.tree.leaves(qp, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(l, QuantizedTensor)
    ]
    for a, b in zip(packed_before, packed_after):
        np.testing.assert_array_equal(a, b)
    # the differentiated tree is exactly the adapter-values tree — the same
    # (…, k, d_out) budget as on a dense base
    st = stats(qp, tr.state.trainable)
    assert 0 < st["fraction"] < 0.05


def test_nf4_base_trains_and_merges_dense(base):
    m, params = base
    qp = quantize_base(params, "nf4")
    peft = get_peft(PeftConfig(method="neuroada", k=2))
    tr = Trainer(m, peft, TrainConfig(learning_rate=1e-2, steps=1, log_every=100), qp)
    data = DataLoader("reasoning", CFG.vocab_size, 16, 32, seed=1)
    tr.run(data, steps=1)
    data.close()
    merged = tr.merged_params()  # dequantizes, then folds the deltas in
    assert not any_quantized(merged)
    for a, b in zip(_leaves(merged), _leaves(dequantize_tree(qp))):
        assert a.shape == b.shape


@pytest.mark.parametrize("qdtype", ["int8", "nf4"])
def test_forward_parity_fp_vs_quantized_base(base, qdtype):
    """Two properties, separately: (1) the quantized *path* is exact — the
    adapted forward on packed weights equals the same forward on the
    dequantized tree; (2) the *noise* the quantization injects vs the fp
    base is bounded at the logit rms scale (random-init reduced models are
    the worst case — near-zero logits don't hide noise in magnitude)."""
    m, params = base
    peft = get_peft(PeftConfig(method="neuroada", k=2))
    tr, aux = peft.init(params, jax.random.PRNGKey(2))
    tr = jax.tree.map(
        lambda v: None if v is None else 0.03 * jnp.ones(v.shape, v.dtype),
        tr, is_leaf=lambda x: x is None,
    )
    batch = {"tokens": jnp.arange(2 * 16, dtype=jnp.int32).reshape(2, 16) % 100}
    eff, ad = peft.model_inputs(params, tr, aux)
    lg_fp, _ = m.forward(eff, ad, batch)
    qp = quantize_base(params, qdtype)
    eff_q, ad_q = peft.model_inputs(qp, tr, aux)
    lg_q, _ = m.forward(eff_q, ad_q, batch)
    # (1) path parity: packed vs explicitly dequantized base, same adapters
    eff_d, ad_d = peft.model_inputs(dequantize_tree(qp), tr, aux)
    lg_deq, _ = m.forward(eff_d, ad_d, batch)
    np.testing.assert_allclose(
        np.asarray(lg_q, np.float32), np.asarray(lg_deq, np.float32), atol=1e-5
    )
    # (2) bounded quantization noise vs the fp32/bf16 base
    rms = lambda a: float((np.asarray(a, np.float32) ** 2).mean() ** 0.5)
    tol = 0.08 if qdtype == "int8" else 0.5
    assert rms(lg_q - lg_fp) <= tol * rms(lg_fp), (rms(lg_q - lg_fp), rms(lg_fp))


def test_quantize_base_rejected_for_dense_trainable_methods(base):
    # masked/full copy params into the trainable tree; a packed base would
    # silently train on dequantized copies — the launcher refuses instead
    from repro.launch.train import main

    with pytest.raises(SystemExit, match="frozen base"):
        main(["--arch", "qwen2-1.5b", "--reduced", "--peft", "masked",
              "--base-dtype", "int8", "--steps", "1"])
