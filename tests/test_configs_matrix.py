"""The 40-cell (arch × shape) matrix contract + config invariants."""

import jax
import pytest

from repro.configs import ARCH_IDS, SHAPES, all_cells, get_config, reduced
from repro.models import get_model


def test_matrix_is_40_cells_with_8_documented_skips():
    cells = all_cells()
    assert len(cells) == 40
    skipped = [c for c in cells if not c[2]]
    assert len(skipped) == 8
    for arch, shape, ok, why in skipped:
        assert shape == "long_500k"
        assert "sub-quadratic" in why
    runnable_long = [c for c in cells if c[1] == "long_500k" and c[2]]
    assert {c[0] for c in runnable_long} == {"zamba2-2.7b", "falcon-mamba-7b"}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_configs_match_assignment(arch):
    cfg = get_config(arch)
    expect = {
        "zamba2-2.7b": (54, 2560, 10240, 32000),
        "qwen3-32b": (64, 5120, 25600, 151936),
        "llama3-405b": (126, 16384, 53248, 128256),
        "qwen2-1.5b": (28, 1536, 8960, 151936),
        "qwen2.5-3b": (36, 2048, 11008, 151936),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 6400, 32064),
        "olmoe-1b-7b": (16, 2048, 1024, 50304),
        "seamless-m4t-large-v2": (24, 1024, 8192, 256206),
        "qwen2-vl-2b": (28, 1536, 8960, 151936),
        "falcon-mamba-7b": (64, 4096, 0, 65024),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size) == expect
    # padded vocab always 128-aligned (TP-16 divisible)
    assert cfg.padded_vocab % 128 == 0
    assert cfg.padded_vocab >= cfg.vocab_size


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_are_abstract(arch, shape):
    """input_specs never allocates: every leaf is a ShapeDtypeStruct."""
    from repro.configs import cell_is_runnable

    cfg = get_config(arch)
    ok, _ = cell_is_runnable(cfg, SHAPES[shape])
    if not ok:
        pytest.skip("documented skip")
    specs = get_model(cfg).input_specs(SHAPES[shape])
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)
    # batch dims match the shape config
    b = SHAPES[shape].global_batch
    if SHAPES[shape].mode == "decode":
        assert specs["token"].shape == (b,)
    else:
        assert specs["tokens"].shape[0] == b


def test_reduced_configs_are_small():
    for arch in ARCH_IDS:
        cfg = reduced(get_config(arch))
        assert cfg.d_model <= 64 and cfg.num_layers <= 2
        assert cfg.vocab_size <= 512
