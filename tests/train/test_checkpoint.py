import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager, load_pytree, save_pytree
from repro.configs import PeftConfig, TrainConfig, get_config, reduced
from repro.data.loader import DataLoader
from repro.models import get_model
from repro.peft import get_peft
from repro.train.trainer import Trainer


def test_pytree_roundtrip(tmp_path):
    tree = {
        "a": {"b": jnp.arange(6).reshape(2, 3), "none": None},
        "c": jnp.ones((4,), jnp.bfloat16),
    }
    p = str(tmp_path / "t.npz")
    save_pytree(p, tree, {"step": 3})
    back = load_pytree(p)
    np.testing.assert_array_equal(back["a"]["b"], np.arange(6).reshape(2, 3))
    assert back["a"]["none"] is None
    assert back["c"].dtype == jnp.bfloat16
    assert os.path.exists(p + ".meta.json")


def test_manager_keep_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (10, 20, 30):
        mgr.save(s, {"x": jnp.full((2,), s)})
    assert mgr.steps() == [20, 30]
    step, tree = mgr.restore_latest()
    assert step == 30
    np.testing.assert_array_equal(tree["x"], [30, 30])


def test_resume_exact(tmp_path):
    """Train 10 steps + save; resume in a fresh Trainer; states identical,
    and continued training matches an uninterrupted run (determinism)."""
    cfg = reduced(get_config("qwen2-1.5b"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    peft = get_peft(PeftConfig(method="neuroada", k=2))

    def mk(ckdir):
        tcfg = TrainConfig(
            learning_rate=3e-3, steps=20, log_every=0,
            checkpoint_every=10, checkpoint_dir=ckdir,
        )
        return Trainer(m, peft, tcfg, params)

    # uninterrupted 20 steps
    tr_full = mk(str(tmp_path / "full"))
    data = DataLoader("lm", cfg.vocab_size, 8, 16, seed=9)
    tr_full.run(data, steps=20)
    data.close()

    # interrupted at 10 + resume
    ck = str(tmp_path / "resumed")
    tr_a = mk(ck)
    data = DataLoader("lm", cfg.vocab_size, 8, 16, seed=9)
    tr_a.run(data, steps=10)
    data.close()
    tr_a.ckpt.wait()

    tr_b = mk(ck)
    start = tr_b.try_resume()
    assert start == 10
    data = DataLoader("lm", cfg.vocab_size, 8, 16, seed=9, start_step=start)
    tr_b.run(data, steps=20)
    data.close()

    for a, b in zip(
        jax.tree.leaves(tr_full.state.trainable), jax.tree.leaves(tr_b.state.trainable)
    ):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6
        )
