import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import PeftConfig, TrainConfig, get_config, reduced
from repro.data.loader import DataLoader, peek_batch
from repro.models import get_model
from repro.peft import get_peft
from repro.train.trainer import Trainer, make_train_step


def _setup(method="neuroada", **tkw):
    cfg = reduced(get_config("qwen2-1.5b"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    peft = get_peft(PeftConfig(method=method, k=2))
    tcfg = TrainConfig(
        learning_rate=3e-3, steps=30, log_every=0, checkpoint_every=0, **tkw
    )
    return cfg, m, params, peft, tcfg


def test_loss_decreases():
    cfg, m, params, peft, tcfg = _setup()
    tr = Trainer(m, peft, tcfg, params)
    data = DataLoader("reasoning", cfg.vocab_size, 16, 32, seed=1)
    hist = tr.run(data, steps=30)
    data.close()
    assert np.mean([h["loss"] for h in hist[-5:]]) < np.mean(
        [h["loss"] for h in hist[:5]]
    )
    assert not any(h["skipped"] for h in hist)


def test_grad_accumulation_equivalence():
    """microbatches=4 grads == full-batch grads (same update direction)."""
    cfg, m, params, peft, _ = _setup()
    rng = jax.random.PRNGKey(0)
    trainable, aux = peft.init(params, rng)
    batch = {k: jnp.asarray(v) for k, v in peek_batch("lm", cfg.vocab_size, 8, 16).items()}

    outs = {}
    for mb in (1, 4):
        tcfg = TrainConfig(learning_rate=1e-3, microbatches=mb, grad_clip=0.0, steps=10)
        step_fn, opt = make_train_step(m, peft, tcfg)
        from repro.train.trainer import TrainState

        state = TrainState(trainable, opt.init(trainable), jnp.zeros((), jnp.int32))
        new_state, metrics = step_fn(params, aux, state, batch)
        outs[mb] = (metrics["loss"], new_state.trainable)
    np.testing.assert_allclose(float(outs[1][0]), float(outs[4][0]), rtol=1e-4)
    l1 = jax.tree.leaves(outs[1][1])
    l4 = jax.tree.leaves(outs[4][1])
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=5e-3
        )


def test_nan_guard_skips_bad_step():
    cfg, m, params, peft, tcfg = _setup()
    tr = Trainer(m, peft, tcfg, params)
    bad = peek_batch("lm", cfg.vocab_size, 8, 16)
    # poison: NaN loss mask propagates into the loss
    bad["loss_mask"] = np.full((8, 15), np.nan, np.float32)
    # snapshot before the step: the state buffers are donated
    state0 = jax.tree.map(lambda x: np.asarray(x, np.float32), tr.state.trainable)
    tr.state, metrics = tr._step_fn(
        tr.params, tr.aux, tr.state, {k: jnp.asarray(v) for k, v in bad.items()}
    )
    assert int(metrics["skipped"]) == 1
    for a, b in zip(jax.tree.leaves(state0), jax.tree.leaves(tr.state.trainable)):
        np.testing.assert_array_equal(a, np.asarray(b, np.float32))


def test_merged_params_match_adapter_forward():
    cfg, m, params, peft, tcfg = _setup()
    tr = Trainer(m, peft, tcfg, params)
    data = DataLoader("reasoning", cfg.vocab_size, 8, 32, seed=2)
    tr.run(data, steps=10)
    data.close()
    batch = {k: jnp.asarray(v) for k, v in peek_batch("reasoning", cfg.vocab_size, 4, 32).items()}
    eff, ad = peft.model_inputs(params, tr.state.trainable, tr.aux)
    lg_ad, _ = m.forward(eff, ad, batch)
    lg_merged, _ = m.forward(tr.merged_params(), None, batch)
    np.testing.assert_allclose(
        np.asarray(lg_ad, np.float32), np.asarray(lg_merged, np.float32), atol=0.15
    )  # bf16 rounding on merge
