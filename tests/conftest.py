# Tests run on the single real CPU device (the 512-device fake platform is
# dryrun.py-only). Keep jax x64 off; seed hypothesis deterministically.
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")
