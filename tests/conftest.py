# Tests run on the single real CPU device (the 512-device fake platform is
# dryrun.py-only). Keep jax x64 off; seed hypothesis deterministically.
# `hypothesis` is optional in the container: guard the import and auto-skip
# the property-based module so collection never dies on the missing dep.
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    from hypothesis import settings

    HAVE_HYPOTHESIS = True
    settings.register_profile("ci", max_examples=20, deadline=None)
    settings.load_profile("ci")
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

collect_ignore_glob = [] if HAVE_HYPOTHESIS else ["core/test_property_core.py"]
