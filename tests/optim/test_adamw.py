import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, apply_updates, clip_by_global_norm, get_schedule


def test_adamw_decreases_quadratic():
    opt = adamw(0.1)
    params = {"w": jnp.asarray([5.0, -3.0]), "skip": None}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.tree.map(
            lambda p: None if p is None else 2 * p, params, is_leaf=lambda x: x is None
        )
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert params["skip"] is None


def test_moments_are_f32_for_bf16_params():
    opt = adamw(0.1)
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.float32
    assert state.nu["w"].dtype == jnp.float32
    updates, _ = opt.update({"w": jnp.ones((4,), jnp.bfloat16)}, state, params)
    assert updates["w"].dtype == jnp.bfloat16  # no FP32 master weights


def test_sparse_state_size_matches_paper_eq6():
    """AdamW state for NeuroAda is 2·d_out·k f32 — by construction."""
    d_out, k = 64, 2
    opt = adamw(1e-3)
    trainable = {"delta": jnp.zeros((k, d_out), jnp.bfloat16)}
    state = opt.init(trainable)
    n = sum(x.size for x in jax.tree.leaves((state.mu, state.nu)))
    assert n == 2 * d_out * k


def test_weight_decay():
    opt = adamw(0.1, weight_decay=0.5)
    params = {"w": jnp.asarray([10.0])}
    state = opt.init(params)
    updates, _ = opt.update({"w": jnp.asarray([0.0])}, state, params)
    assert float(updates["w"][0]) < 0  # pure decay pulls toward 0


def test_clip_by_global_norm():
    grads = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], atol=1e-5)
    same, _ = clip_by_global_norm(grads, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0, 4.0], atol=1e-5)


def test_schedules():
    for name in ("linear", "cosine", "constant"):
        fn = get_schedule(name, 1e-3, 100, 0.1)
        v0 = float(fn(jnp.int32(0)))
        vp = float(fn(jnp.int32(10)))
        ve = float(fn(jnp.int32(100)))
        assert vp >= v0
        assert vp <= 1e-3 + 1e-9
        if name != "constant":
            assert ve <= vp
