import numpy as np
import pytest

from repro.data.loader import DataLoader, peek_batch
from repro.data.synthetic import TASKS, arithmetic_task, reasoning_task


def test_streams_are_step_deterministic():
    a = TASKS["lm"](512, 4, 16, seed=7, step=3)
    b = TASKS["lm"](512, 4, 16, seed=7, step=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = TASKS["lm"](512, 4, 16, seed=7, step=4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_reasoning_mapping_is_task_level_not_stream_level():
    """Different stream seeds must share the pattern→answer mapping (the
    train/eval contract)."""
    a = reasoning_task(512, 256, 32, seed=1, step=0)
    b = reasoning_task(512, 256, 32, seed=2, step=0)
    # build pattern->answer maps from each stream; overlapping patterns agree
    def mapping(batch):
        out = {}
        for row, ans in zip(batch["tokens"], batch["answer"]):
            out[tuple(row[1:5])] = int(ans)
        return out

    ma, mb = mapping(a), mapping(b)
    common = set(ma) & set(mb)
    assert common
    assert all(ma[k] == mb[k] for k in common)


def test_reasoning_mask_marks_answer_position():
    b = reasoning_task(512, 8, 32, seed=3, step=0)
    for i in range(8):
        pos = int(b["answer_pos"][i])
        # loss_mask is aligned with targets[:,1:]: index pos-1 ⇒ column pos
        assert b["loss_mask"][i, pos - 1] == 1.0
        assert b["loss_mask"][i].sum() == 1.0
        assert b["tokens"][i, pos] == b["answer"][i]


def test_arithmetic_mask_covers_answer_digits():
    b = arithmetic_task(512, 16, 32, seed=4, step=0)
    assert b["loss_mask"].sum() > 0
    # masked targets are digits or eos
    tgt = b["targets"][:, 1:]
    masked = tgt[b["loss_mask"] > 0]
    assert np.all(((masked >= 16) & (masked < 26)) | (masked == 2))


def test_loader_start_step_resumes_stream():
    d1 = DataLoader("lm", 512, 4, 16, seed=5)
    batches = [next(d1) for _ in range(4)]
    d1.close()
    d2 = DataLoader("lm", 512, 4, 16, seed=5, start_step=2)
    resumed = next(d2)
    d2.close()
    np.testing.assert_array_equal(resumed["tokens"], batches[2]["tokens"])


def test_loader_rejects_bad_host_split():
    with pytest.raises(ValueError):
        DataLoader("lm", 512, 5, 16, host_count=2)
