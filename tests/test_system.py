"""End-to-end behaviour of the NeuroAda system: the paper's Alg. 1 pipeline
(select → sparse-train → merge → serve) plus the core paper claims at
smoke scale."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import PeftConfig, TrainConfig, get_config, reduced
from repro.data.loader import DataLoader, peek_batch
from repro.models import get_model
from repro.peft import get_peft, stats
from repro.serve.engine import ServeEngine
from repro.train.trainer import Trainer


def test_full_neuroada_pipeline():
    cfg = reduced(get_config("qwen2-1.5b"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))

    # Phase 1+2: select + sparse train
    peft = get_peft(PeftConfig(method="neuroada", k=2))
    tcfg = TrainConfig(learning_rate=3e-3, steps=80, log_every=0, checkpoint_every=0)
    tr = Trainer(m, peft, tcfg, params)
    st = stats(params, tr.state.trainable)
    assert st["fraction"] < 0.06  # featherlight
    data = DataLoader("reasoning", cfg.vocab_size, 16, 32, seed=3)
    hist = tr.run(data, steps=80)
    data.close()
    assert hist[-1]["loss"] < hist[0]["loss"]

    # Phase 3: merge — zero inference overhead, same structure
    merged = tr.merged_params()
    assert jax.tree.structure(merged) == jax.tree.structure(params)

    # Serve the merged model
    eng = ServeEngine(m, merged, slots=2, max_len=64)
    eng.submit([1, 20, 30], max_new=4)
    reqs = eng.run_to_completion()
    assert len(reqs[0].out) == 4

    # the adaptation actually moved predictions vs the base model
    batch = {k: jnp.asarray(v) for k, v in peek_batch("reasoning", cfg.vocab_size, 4, 32).items()}
    lg_base, _ = m.forward(params, None, batch)
    lg_tuned, _ = m.forward(merged, None, batch)
    assert float(jnp.max(jnp.abs(lg_base.astype(jnp.float32) - lg_tuned.astype(jnp.float32)))) > 0.01


def test_adaptation_accuracy_on_task():
    """NeuroAda k=2 reaches high answer accuracy on the synthetic
    commonsense-style task (the Fig. 4 measurement at smoke scale)."""
    cfg = reduced(get_config("qwen2-1.5b"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    peft = get_peft(PeftConfig(method="neuroada", k=2))
    tcfg = TrainConfig(learning_rate=5e-3, steps=150, log_every=0, checkpoint_every=0)
    tr = Trainer(m, peft, tcfg, params)
    data = DataLoader("reasoning", cfg.vocab_size, 32, 32, seed=4)
    tr.run(data, steps=150)
    data.close()

    eff, ad = peft.model_inputs(params, tr.state.trainable, tr.aux)
    test = peek_batch("reasoning", cfg.vocab_size, 64, 32, seed=999)
    logits, _ = m.forward(eff, ad, {k: jnp.asarray(v) for k, v in test.items()})
    pred_pos = test["answer_pos"][0] - 1  # predicting token AT answer_pos
    preds = np.argmax(np.asarray(logits[:, pred_pos, : cfg.vocab_size], np.float32), -1)
    acc = float(np.mean(preds == test["answer"]))
    base_logits, _ = m.forward(params, None, {k: jnp.asarray(v) for k, v in test.items()})
    base = np.argmax(np.asarray(base_logits[:, pred_pos, : cfg.vocab_size], np.float32), -1)
    base_acc = float(np.mean(base == test["answer"]))
    assert acc > base_acc + 0.2, (acc, base_acc)


def test_data_loader_host_sharding_and_determinism():
    full = DataLoader("lm", 512, 8, 16, seed=5)
    b_full = next(full)
    full.close()
    parts = []
    for hid in range(2):
        dl = DataLoader("lm", 512, 8, 16, seed=5, host_id=hid, host_count=2)
        parts.append(next(dl))
        dl.close()
    recomposed = np.concatenate([parts[0]["tokens"], parts[1]["tokens"]], axis=0)
    np.testing.assert_array_equal(recomposed, b_full["tokens"])
